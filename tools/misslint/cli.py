"""misslint command line.

    python -m tools.misslint src/repro                  # packaged baseline
    python -m tools.misslint src/repro --baseline B     # explicit baseline
    python -m tools.misslint src/repro --no-baseline    # raw findings
    python -m tools.misslint src/repro --write-baseline # accept the present

Exit codes: 0 clean (modulo baseline), 1 violations (or stale baseline
entries under --strict-baseline), 2 usage error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .core import (RULES, apply_baseline, lint_paths, load_baseline,
                   write_baseline, _load_rules)

_DEFAULT_BASELINE = Path(__file__).parent / "baseline.txt"


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m tools.misslint",
        description="trace-safety / determinism / recompile static "
                    "analysis for the MISS serving stack")
    p.add_argument("paths", nargs="*", default=["src/repro"],
                   help="files or directories to lint (default: src/repro)")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help=f"baseline file (default: {_DEFAULT_BASELINE} "
                        f"when it exists)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore any baseline; report every violation")
    p.add_argument("--write-baseline", action="store_true",
                   help="write the current violations as the new baseline "
                        "and exit 0")
    p.add_argument("--select", default=None, metavar="RULES",
                   help="comma-separated rule ids or families "
                        "(e.g. ML303,prng)")
    p.add_argument("--strict-baseline", action="store_true",
                   help="fail (exit 1) on stale baseline entries")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    p.add_argument("--rel-to", default=None, metavar="DIR",
                   help="base directory for reported paths/fingerprints "
                        "(default: cwd)")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        _load_rules()
        fam = None
        for r in sorted(RULES.values(), key=lambda r: r.id):
            if r.family != fam:
                fam = r.family
                print(f"[{fam}]")
            print(f"  {r.id}  {r.summary}")
        return 0

    select = args.select.split(",") if args.select else None
    try:
        violations = lint_paths(args.paths, select=select,
                                rel_to=args.rel_to)
    except ValueError as e:
        print(f"misslint: {e}", file=sys.stderr)
        return 2

    baseline_path = args.baseline
    if baseline_path is None and _DEFAULT_BASELINE.exists() \
            and not args.no_baseline:
        baseline_path = str(_DEFAULT_BASELINE)

    if args.write_baseline:
        target = baseline_path or str(_DEFAULT_BASELINE)
        write_baseline(target, violations)
        print(f"misslint: wrote {len(violations)} baseline entries to "
              f"{target}")
        return 0

    baseline = {} if (args.no_baseline or baseline_path is None) \
        else load_baseline(baseline_path)
    fresh, stale = apply_baseline(violations, baseline)

    for v in fresh:
        print(v.format())
    if stale:
        print(f"\nmisslint: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (debt paid -- delete "
              f"the lines):", file=sys.stderr)
        for line in stale:
            print(f"  {line}", file=sys.stderr)

    suppressed = len(violations) - len(fresh)
    if fresh:
        print(f"\nmisslint: {len(fresh)} violation"
              f"{'' if len(fresh) == 1 else 's'}"
              + (f" ({suppressed} baselined)" if suppressed else ""),
              file=sys.stderr)
        return 1
    if stale and args.strict_baseline:
        return 1
    print(f"misslint: clean"
          + (f" ({suppressed} baselined)" if suppressed else ""))
    return 0
