"""Pallas kernel-contract rules (kernels/*/kernel.py and their wrappers).

ML501 -- every ref a kernel body writes must have at least one
``pl.when``-guarded store site.  The repo's grids over-cover (n/B padded to
tile multiples, lane grids with inactive groups): an output ref whose ONLY
stores are unconditional top-level writes has no predication anywhere --
padded/inactive tiles write garbage that the jnp oracle never sees, and
interpret-mode parity hides it (the oracle masks, the kernel doesn't).
The sanctioned idioms both pass: init-under-``pl.when(idx == 0)`` with
top-level accumulation (flash-attention style), and fully predicated
stores (the poisson_bootstrap gating).

ML502 -- a ``//`` in the grid computation without a divisibility guard
(an ``assert``/``raise`` mentioning ``%``) in the same function: a
non-multiple shape silently drops the remainder tiles.

ML503 -- ref-oracle signature drift: ``<name>_ref`` in ref.py must keep
its positional parameters a prefix-match of ``<name>`` in the sibling
kernel.py/ops.py.  The parity tests call both with the same argument list;
a reordered parameter turns them into tests of nothing.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import Dict, List, Set, Tuple

from .. import astutil
from ..astutil import call_name, dotted_name, last_segment, own_scope_walk
from ..core import rule


def _is_kernel_file(relpath: str) -> bool:
    p = PurePosixPath(relpath)
    return "kernels" in p.parts and p.name == "kernel.py"


def _ref_params(fn: ast.AST) -> Set[str]:
    return {a for a in astutil.positional_params(fn) if a.endswith("_ref")}


def _stored_refs(node: ast.AST, refs: Set[str]) -> Set[str]:
    """Ref names stored to (subscript assignment) in ``node``'s own scope."""
    out: Set[str] = set()
    for sub in own_scope_walk(node):
        targets: List[ast.AST] = []
        if isinstance(sub, ast.Assign):
            targets = sub.targets
        elif isinstance(sub, ast.AugAssign):
            targets = [sub.target]
        for tgt in targets:
            if isinstance(tgt, ast.Subscript) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id in refs:
                out.add(tgt.value.id)
    return out


def _is_when_guarded(fn: ast.AST) -> bool:
    for dec in fn.decorator_list:
        d = dec.func if isinstance(dec, ast.Call) else dec
        if last_segment(dotted_name(d)) == "when":
            return True
    return False


@rule("ML501", "pallas",
      "kernel ref with no pl.when-guarded store site")
def check_unguarded_store(ctx):
    if not _is_kernel_file(ctx.relpath):
        return []
    out: List = []
    for fn in astutil.function_defs(ctx.tree):
        refs = _ref_params(fn)
        if not refs:
            continue
        top_level = _stored_refs(fn, refs)
        guarded: Set[str] = set()
        for node in ast.walk(fn):
            if node is fn or not isinstance(node, astutil.FuncNode):
                continue
            if _is_when_guarded(node):
                guarded |= _stored_refs(node, refs)
            else:
                # unguarded nested def (e.g. a helper called in-line)
                top_level |= _stored_refs(node, refs)
        for ref in sorted(top_level - guarded):
            out.append(ctx.violation(
                fn, "ML501",
                f"`{ref}` in `{fn.name}` is only ever stored "
                f"unconditionally -- with an over-covering grid the "
                f"padded/inactive tiles write garbage; guard the store "
                f"(or its init) with pl.when"))
    return out


@rule("ML502", "pallas",
      "grid tile division without a divisibility guard")
def check_grid_divisibility(ctx):
    if not _is_kernel_file(ctx.relpath):
        return []
    out: List = []
    for fn in astutil.function_defs(ctx.tree):
        grid_exprs: List[ast.AST] = []
        for node in own_scope_walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == "grid":
                        grid_exprs.append(node.value)
            elif isinstance(node, ast.keyword) and node.arg == "grid":
                grid_exprs.append(node.value)
        if not grid_exprs:
            continue
        has_floordiv = any(
            isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.FloorDiv)
            for g in grid_exprs for sub in ast.walk(g))
        if not has_floordiv:
            continue
        guarded = False
        for node in own_scope_walk(fn):
            if isinstance(node, (ast.Assert, ast.If)):
                test = node.test
                if any(isinstance(s, ast.BinOp)
                       and isinstance(s.op, ast.Mod)
                       for s in ast.walk(test)):
                    guarded = True
                    break
        if not guarded:
            out.append(ctx.violation(
                fn, "ML502",
                f"`{fn.name}` computes its grid with `//` but never "
                f"checks divisibility -- a non-multiple shape silently "
                f"drops the remainder tiles; assert `x % tile == 0` (or "
                f"round up and predicate)"))
    return out


def _positional_sig(fn: ast.AST) -> Tuple[str, ...]:
    return tuple(astutil.positional_params(fn))


@rule("ML503", "pallas",
      "kernel-vs-ref entry point signature drift", scope="tree")
def check_ref_signature_drift(ctxs):
    out: List = []
    by_dir: Dict[str, Dict[str, "FileContext"]] = {}
    for ctx in ctxs:
        p = PurePosixPath(ctx.relpath)
        if "kernels" not in p.parts:
            continue
        by_dir.setdefault(str(p.parent), {})[p.name] = ctx
    for dirname, files in sorted(by_dir.items()):
        ref_ctx = files.get("ref.py")
        if ref_ctx is None:
            continue
        impl_sigs: Dict[str, Tuple[Tuple[str, ...], str]] = {}
        for impl_name in ("ops.py", "kernel.py", "__init__.py"):
            impl_ctx = files.get(impl_name)
            if impl_ctx is None:
                continue
            for fn in astutil.function_defs(impl_ctx.tree):
                impl_sigs.setdefault(
                    fn.name, (_positional_sig(fn), impl_name))
        for fn in astutil.function_defs(ref_ctx.tree):
            if not fn.name.endswith("_ref"):
                continue
            stem = fn.name[:-len("_ref")]
            if stem not in impl_sigs:
                continue
            ref_pos = _positional_sig(fn)
            impl_pos, impl_file = impl_sigs[stem]
            n = min(len(ref_pos), len(impl_pos))
            if ref_pos[:n] != impl_pos[:n]:
                out.append(ref_ctx.violation(
                    fn, "ML503",
                    f"`{fn.name}` positional args {ref_pos[:n]} drifted "
                    f"from `{stem}` in {dirname}/{impl_file} "
                    f"{impl_pos[:n]} -- the parity tests now compare "
                    f"different operand orders"))
    return out
