"""Determinism rules.

Lane ordering, pytree structure, and cache signatures must be pure
functions of the inputs: the bit-parity contracts (mesh-vs-solo, replay,
warm cache) compare trajectories ACROSS processes, so any iteration order
that can differ between interpreter runs -- or any ambient entropy --
breaks them without failing locally.

ML401 -- iteration over a set expression (``set()``/``{...}``/
``frozenset()``) feeding a for loop, comprehension, or tuple/list
materialization.  Set order is salted per process; wrap in ``sorted()``.

ML402 -- ambient nondeterminism under ``core/`` and ``kernels/``:
``import random`` (the global Mersenne Twister), ``time.time`` (wall
clock; ``perf_counter`` for durations is fine), and unseeded
``np.random.*`` module-level samplers (``default_rng(seed)`` /
``Generator`` are the sanctioned numpy entry points).
"""
from __future__ import annotations

import ast
from typing import List

from ..astutil import call_name, dotted_name, last_segment
from ..core import rule


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return last_segment(call_name(node)) in ("set", "frozenset")
    return False


@rule("ML401", "determinism",
      "iteration over an unordered set expression")
def check_set_iteration(ctx):
    out: List = []
    for node in ast.walk(ctx.tree):
        iters: List[ast.AST] = []
        if isinstance(node, ast.For):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters.extend(gen.iter for gen in node.generators)
        elif isinstance(node, ast.Call) \
                and last_segment(call_name(node)) in ("tuple", "list",
                                                      "enumerate") \
                and node.args:
            iters.append(node.args[0])
        for it in iters:
            if _is_set_expr(it):
                out.append(ctx.violation(
                    it, "ML401",
                    "iterating a set -- order is salted per process; any "
                    "lane ordering / pytree / cache signature built from "
                    "it differs across runs.  Wrap in sorted()"))
    return out


def _deterministic_scope(relpath: str) -> bool:
    p = f"/{relpath}"
    return "/core/" in p or "/kernels/" in p


_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
                 "PCG64", "Philox"}


@rule("ML402", "determinism",
      "wall clock / global RNG under core/ or kernels/")
def check_ambient_entropy(ctx):
    if not _deterministic_scope(ctx.relpath):
        return []
    out: List = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "random":
                    out.append(ctx.violation(
                        node, "ML402",
                        "`import random` under core/ -- the global "
                        "Mersenne Twister is process-global ambient "
                        "state; use the counter PRNG (kernels/prng.py) "
                        "or a seeded np.random.default_rng"))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "random":
                out.append(ctx.violation(
                    node, "ML402",
                    "`from random import ...` under core/ -- use the "
                    "counter PRNG or a seeded np.random.default_rng"))
        elif isinstance(node, ast.Call):
            name = call_name(node)
            if not name:
                continue
            if name in ("time.time", "time"):
                # Accept time(...) only when it is clearly time.time.
                if name == "time" and not isinstance(node.func,
                                                     ast.Attribute):
                    continue
                out.append(ctx.violation(
                    node, "ML402",
                    "time.time() under core/ -- wall clock leaks into a "
                    "deterministic path (durations: time.perf_counter; "
                    "timestamps belong to the serving layer)"))
            elif name.startswith(("np.random.", "numpy.random.")) \
                    and last_segment(name) not in _NP_RANDOM_OK:
                out.append(ctx.violation(
                    node, "ML402",
                    f"`{name}(...)` draws from numpy's GLOBAL rng under "
                    f"core/ -- seed an explicit np.random.default_rng"))
    return out
