"""Trace-safety rules.

ML101 -- Python control flow (`if`/`while`/`assert`) on traced values
inside jit-reachable bodies, and host concretization (`float()`, `.item()`,
`np.asarray()`) of traced values under trace.  Either aborts tracing with a
ConcretizationTypeError at best; at worst (a value that happens to be
concrete at trace time, e.g. a closure constant) it silently bakes one
branch into the compiled program and the determinism contract breaks only
for the shapes that retraced differently.

ML102 -- host synchronization in the serving pump path.  `pump()`/`tick()`
rounds are sync-free by contract (DESIGN.md phase F): the ONLY device
reads are the explicit `jax.device_get` calls at harvest points.  An
`.item()` / `float()` / `np.asarray()` on a device value anywhere else in
the round blocks the host on the step's completion and serializes the
dispatch pipeline -- the exact tail-latency class PR 9's pre-warmed key
buckets were added to kill.  The runtime teeth for this rule live in
repro.core.sanitize (transfer-guard over LanePool.tick).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .. import astutil
from ..astutil import (TRACED_CALL_ROOTS, call_name, dotted_name,
                       flatten_target_names, last_segment, own_scope_walk)
from ..core import rule

_CONCRETIZERS = {"float", "int", "bool"}
_NP_SYNC = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
            "onp.asarray", "onp.array"}


def _is_traced_call(node: ast.Call) -> bool:
    name = call_name(node)
    return bool(name) and (name.startswith(TRACED_CALL_ROOTS)
                           or name in ("jnp", "lax"))


def _tainted_names(fn: ast.AST) -> Set[str]:
    """Names assigned (in the fn's own scope) from jnp/lax expressions,
    propagated to fixpoint through arithmetic/subscripts/attributes."""
    tainted: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for node in own_scope_walk(fn):
            if not isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                continue
            value = node.value
            if value is None:
                continue
            is_traced = False
            for sub in ast.walk(value):
                if isinstance(sub, ast.Call) and _is_traced_call(sub):
                    is_traced = True
                    break
                d = dotted_name(sub)
                if d and (d in tainted or d.split(".", 1)[0] in tainted):
                    is_traced = True
                    break
            if not is_traced:
                continue
            for tgt in astutil.assign_targets(node):
                for name in flatten_target_names(tgt):
                    if name not in tainted:
                        tainted.add(name)
                        changed = True
    return tainted


def _is_none_check(test: ast.AST) -> bool:
    return (isinstance(test, ast.Compare)
            and len(test.ops) == 1
            and isinstance(test.ops[0], (ast.Is, ast.IsNot)))


@rule("ML101", "trace-safety",
      "Python branch / host concretization on a traced value under jit")
def check_traced_branch(ctx):
    out: List = []
    for fn in ctx.jit_reachable:
        tainted = _tainted_names(fn)

        def touches_traced(expr: ast.AST) -> bool:
            for sub in ast.walk(expr):
                if isinstance(sub, ast.Call) and _is_traced_call(sub):
                    return True
                d = dotted_name(sub)
                if d and (d in tainted or d.split(".", 1)[0] in tainted):
                    return True
            return False

        for node in own_scope_walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.Assert, ast.IfExp)):
                test = node.test
                if _is_none_check(test):
                    continue
                if touches_traced(test):
                    kind = type(node).__name__.lower()
                    out.append(ctx.violation(
                        node, "ML101",
                        f"`{kind}` on a traced value inside a jitted body "
                        f"-- use lax.cond/select/while_loop (or hoist the "
                        f"decision to a static argument)"))
            elif isinstance(node, ast.Call):
                name = call_name(node)
                seg = last_segment(name)
                if seg == "item" and isinstance(node.func, ast.Attribute):
                    recv = node.func.value
                    if touches_traced(recv):
                        out.append(ctx.violation(
                            node, "ML101",
                            ".item() on a traced value aborts tracing "
                            "(host sync under jit)"))
                elif (name in _CONCRETIZERS or name in _NP_SYNC) \
                        and node.args and touches_traced(node.args[0]):
                    out.append(ctx.violation(
                        node, "ML101",
                        f"{name}() concretizes a traced value inside a "
                        f"jitted body"))
    return out


# -- ML102: pump-path host syncs -------------------------------------------

_PUMP_ROOTS = ("pump", "tick", "drain")
_PUMP_PREFIXES = ("_tick", "_pump")

# Imported step entry points known to return device values.
_KNOWN_DEVICE_FNS = {"fused_step", "make_sharded_step", "fused_l2miss",
                     "fused_l2miss_lanes", "fused_grouped"}


def _is_pump_module(relpath: str) -> bool:
    return "/serve/" in f"/{relpath}"


def _module_jitted_defs(ctx) -> Set[str]:
    """Module-level defs that are jit-wrapped (decorator or name = jax.jit)."""
    jitted: Set[str] = set()
    for fn in astutil.function_defs(ctx.tree):
        if astutil.is_jit_decorated(fn):
            jitted.add(fn.name)
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            seg = last_segment(call_name(node.value))
            if seg in ("jit", "pjit"):
                for tgt in node.targets:
                    d = dotted_name(tgt)
                    if d:
                        jitted.add(last_segment(d))
    return jitted


def _called_names(fn: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in own_scope_walk(fn):
        if isinstance(node, ast.Call):
            seg = last_segment(call_name(node))
            if seg:
                out.add(seg)
    return out


def _pump_path_functions(ctx) -> List[ast.AST]:
    """Transitive same-module closure from pump()/tick()/drain() roots,
    resolving calls by bare name (self.foo(...) -> foo)."""
    fns = astutil.function_defs(ctx.tree)
    by_name: Dict[str, List[ast.AST]] = {}
    for fn in fns:
        by_name.setdefault(fn.name, []).append(fn)
    frontier = [fn for fn in fns
                if fn.name in _PUMP_ROOTS
                or fn.name.startswith(_PUMP_PREFIXES)]
    reach: Set[ast.AST] = set(frontier)
    while frontier:
        fn = frontier.pop()
        for callee in _called_names(fn):
            for target in by_name.get(callee, ()):
                if target not in reach:
                    reach.add(target)
                    frontier.append(target)
    return list(reach)


@rule("ML102", "trace-safety",
      "implicit device->host sync in the serving pump path")
def check_pump_path_sync(ctx):
    if not _is_pump_module(ctx.relpath):
        return []
    out: List = []
    device_fns = _module_jitted_defs(ctx) | _KNOWN_DEVICE_FNS

    for fn in _pump_path_functions(ctx):
        # Taint: names bound from device-returning calls; device_get
        # launders (its results are host numpy by construction).
        tainted: Set[str] = set()
        for node in own_scope_walk(fn):
            if not isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                continue
            value = node.value
            if value is None:
                continue
            launders = any(
                isinstance(s, ast.Call)
                and last_segment(call_name(s)) == "device_get"
                for s in ast.walk(value))
            taints = not launders and any(
                isinstance(s, ast.Call)
                and last_segment(call_name(s)) in device_fns
                for s in ast.walk(value))
            for tgt in astutil.assign_targets(node):
                for name in flatten_target_names(tgt):
                    if taints:
                        tainted.add(name)
                    elif name in tainted:    # reassigned clean
                        tainted.discard(name)

        def is_device(expr: ast.AST) -> bool:
            for sub in ast.walk(expr):
                d = dotted_name(sub)
                if d and (d in tainted or d.split(".", 1)[0] in tainted):
                    return True
                if isinstance(sub, ast.Call) \
                        and last_segment(call_name(sub)) in device_fns:
                    return True
            return False

        for node in own_scope_walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            seg = last_segment(name)
            if seg == "item" and isinstance(node.func, ast.Attribute) \
                    and is_device(node.func.value):
                out.append(ctx.violation(
                    node, "ML102",
                    ".item() on a device value in the pump path -- blocks "
                    "the host on the in-flight step; read at the harvest "
                    "point via jax.device_get"))
            elif (name in _CONCRETIZERS or name in _NP_SYNC) \
                    and node.args and is_device(node.args[0]):
                out.append(ctx.violation(
                    node, "ML102",
                    f"{name}() on a device value in the pump path forces "
                    f"an implicit device->host sync; use jax.device_get at "
                    f"an explicit harvest point"))
    return out
