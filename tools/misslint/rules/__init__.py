"""Importing this package registers every rule with the core registry."""
from . import determinism, pallas, prng, recompile, trace_safety  # noqa: F401
