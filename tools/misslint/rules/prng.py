"""PRNG-discipline rules.

Every bit-parity assertion in the repo (lane-vs-solo, mesh-vs-host,
warm-vs-cold, migration) rests on ONE property: the sampling and bootstrap
streams are pure functions of (seed, slot, replicate) counters rooted at a
small number of audited key-construction sites.  A stray
``jax.random.PRNGKey(...)`` deep in a helper silently forks a new stream --
nothing fails until two paths that must agree draw from different roots.

ML201 -- raw key construction outside the sanctioned sites
(core/sampling.py owns ``root_key`` and the SampleStore; session/pool
``__init__`` are the serving roots).  Deliberate exceptions (the launch/
model-training scaffolding) are carried in the baseline file, visibly.

ML202 -- the same key consumed by more than one sampler without an
intervening ``split``/``fold_in``: the draws are identical, which is
correlated-sample corruption, not randomness.
"""
from __future__ import annotations

import ast
from typing import List, Set

from .. import astutil
from ..astutil import call_name, dotted_name, flatten_target_names, \
    last_segment, own_scope_walk
from ..core import rule

# (relpath suffix, qualname prefix or None = whole module)
_SANCTIONED = (
    ("core/sampling.py", None),
    ("serve/session.py", "AQPSession.__init__"),
    ("serve/lane_pool.py", "LanePool.__init__"),
)

_KEY_CTORS = {"PRNGKey", "key"}


def _is_key_ctor(node: ast.Call) -> bool:
    name = call_name(node)
    if not name:
        return False
    seg = last_segment(name)
    if seg == "PRNGKey":
        return True
    # ``jax.random.key`` only -- a bare ``key(...)`` is anything.
    return seg == "key" and name.endswith("random.key")


@rule("ML201", "prng",
      "raw PRNGKey construction outside a sanctioned site")
def check_raw_key(ctx):
    out: List = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and _is_key_ctor(node)):
            continue
        scope = ctx.scope_of(node)
        sanctioned = False
        for suffix, prefix in _SANCTIONED:
            if ctx.relpath.endswith(suffix) and (
                    prefix is None or scope == prefix
                    or scope.startswith(prefix + ".")):
                sanctioned = True
                break
        if not sanctioned:
            out.append(ctx.violation(
                node, "ML201",
                "raw PRNGKey construction outside the sanctioned sites "
                "(sampling.root_key / SampleStore / session/pool init) "
                "forks an unaudited stream -- derive via "
                "sampling.root_key, split, or fold_in"))
    return out


_DERIVERS = {"split", "fold_in", "key_data", "wrap_key_data", "clone",
             "PRNGKey", "key", "root_key"}


def _random_root(name: str) -> bool:
    """Heuristic: dotted path through a jax.random-ish module."""
    return (name.startswith(("jax.random.", "jrandom.", "jr."))
            or ".random." in name)


@rule("ML202", "prng",
      "key consumed by >1 sampler without split/fold_in")
def check_key_reuse(ctx):
    out: List = []
    for fn in astutil.function_defs(ctx.tree):
        keys: Set[str] = set()
        used_at = {}

        def handle_expr(expr: ast.AST):
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if not name or not _random_root(name):
                    continue
                seg = last_segment(name)
                if seg in _DERIVERS:
                    continue
                for arg in list(node.args) + \
                        [kw.value for kw in node.keywords]:
                    d = dotted_name(arg)
                    if d in keys:
                        if d in used_at:
                            out.append(ctx.violation(
                                node, "ML202",
                                f"key `{d}` already consumed at line "
                                f"{used_at[d]} -- identical draws; "
                                f"split/fold_in a fresh subkey per "
                                f"consumer"))
                        else:
                            used_at[d] = node.lineno

        def handle_stmt(stmt: ast.AST):
            # uses in the value first, THEN target rebinding resets state
            # (`self.key, sub = split(self.key)` is the sanctioned idiom).
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                if stmt.value is not None:
                    handle_expr(stmt.value)
                    fresh = any(
                        isinstance(s, ast.Call)
                        and last_segment(call_name(s)) in
                        ("split", "fold_in", "PRNGKey", "root_key")
                        for s in ast.walk(stmt.value))
                    for tgt in astutil.assign_targets(stmt):
                        for name in flatten_target_names(tgt):
                            used_at.pop(name, None)
                            if fresh:
                                keys.add(name)
                            else:
                                keys.discard(name)
            elif isinstance(stmt, ast.Expr):
                handle_expr(stmt.value)
            else:
                for child in ast.iter_child_nodes(stmt):
                    if isinstance(child, astutil.FuncNode
                                  + (ast.ClassDef, ast.Lambda)):
                        continue
                    if isinstance(child, ast.stmt):
                        handle_stmt(child)
                    elif isinstance(child, ast.expr):
                        handle_expr(child)

        for stmt in fn.body:
            handle_stmt(stmt)
    return out
