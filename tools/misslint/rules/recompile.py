"""Recompile-hygiene rules.

The serving contract is "one compiled program per pool" (DESIGN.md phases
C-J): a steady-state recompile costs 100ms-seconds in the middle of the
dispatch hot path and shows up only as a latency-tail cliff (the PR 9
``_unstack`` bug).  These rules catch the static patterns that cause it.

ML301 -- jit boundary drift: ``static_argnames`` naming a parameter that
does not exist on the decorated function (silent: jax only errors when a
caller passes it), a static parameter with a mutable (unhashable) default,
or ``jax.jit`` applied directly to a lambda expression.

ML302 -- a fresh callable jitted per call: ``jax.jit(local_fn)`` inside an
un-memoized function body creates a NEW jit wrapper -- and a new compile
cache -- on every invocation.  The sanctioned pattern is an
``lru_cache``-decorated factory (see core/l2miss._estimate_fn).

ML303 -- compiled-program caches without a sane bound: an unbounded
``lru_cache``/``functools.cache`` on a jit-returning factory pins every
program it ever built (a long-lived server cycling configurations leaks
compiled executables); an oversized bound (> 64) is the same leak with a
delay (core/fused bounds its sharded-step memo to 16 for exactly this
reason).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .. import astutil
from ..astutil import call_name, decorator_calls, dotted_name, last_segment
from ..core import rule

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                     ast.SetComp)


def _jit_call_of(dec: ast.AST) -> Optional[ast.Call]:
    """The Call node carrying jit kwargs for @jax.jit(...)/@partial(jax.jit,...)."""
    if not isinstance(dec, ast.Call):
        return None
    name = call_name(dec)
    seg = last_segment(name)
    if seg in ("jit", "pjit"):
        return dec
    if seg == "partial" and dec.args:
        inner = last_segment(dotted_name(dec.args[0]))
        if inner in ("jit", "pjit"):
            return dec
    return None


def _static_names(call: ast.Call) -> Optional[List[str]]:
    """Literal static_argnames, or None when not statically resolvable."""
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in v.elts):
            return [e.value for e in v.elts]
        return None
    return None


@rule("ML301", "recompile",
      "jit boundary: static_argnames drift / unhashable static default / "
      "jitted lambda")
def check_jit_boundary(ctx):
    out: List = []
    for fn in astutil.function_defs(ctx.tree):
        params = set(astutil.positional_params(fn)
                     + astutil.keyword_only_params(fn))
        defaults = {}
        args = fn.args
        pos = list(args.posonlyargs) + list(args.args)
        for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
            defaults[a.arg] = d
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if d is not None:
                defaults[a.arg] = d
        for dec in decorator_calls(fn):
            call = _jit_call_of(dec)
            if call is None:
                continue
            statics = _static_names(call)
            if statics is None:
                continue
            for s in statics:
                if s not in params:
                    out.append(ctx.violation(
                        dec, "ML301",
                        f"static_argnames names `{s}` which is not a "
                        f"parameter of `{fn.name}` -- signature drift; "
                        f"callers passing it will get a jax error, "
                        f"callers relying on it being static won't"))
                elif isinstance(defaults.get(s), _MUTABLE_LITERALS):
                    out.append(ctx.violation(
                        dec, "ML301",
                        f"static parameter `{s}` of `{fn.name}` has an "
                        f"unhashable (mutable) default -- every call with "
                        f"the default raises or recompiles; use a tuple / "
                        f"frozen value"))
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) \
                and last_segment(call_name(node)) in ("jit", "pjit") \
                and node.args and isinstance(node.args[0], ast.Lambda):
            out.append(ctx.violation(
                node, "ML301",
                "jax.jit(lambda ...) -- a new callable (and compile-cache "
                "key) at every evaluation site; name the function"))
    return out


@rule("ML302", "recompile",
      "jit of a per-call local callable outside a memoized factory")
def check_jit_factory(ctx):
    out: List = []
    for fn in astutil.function_defs(ctx.tree):
        if astutil.has_cache_decorator(fn):
            continue
        local_names = set()
        for node in astutil.own_scope_walk(fn):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                for tgt in astutil.assign_targets(node):
                    for name in astutil.flatten_target_names(tgt):
                        if "." not in name:
                            local_names.add(name)
        for node in ast.walk(fn):
            if node is fn or not isinstance(node, astutil.FuncNode):
                continue
            local_names.add(node.name)
        for node in astutil.own_scope_walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if last_segment(call_name(node)) not in ("jit", "pjit"):
                continue
            if not node.args:
                continue
            target = node.args[0]
            # lambdas are ML301's finding; flagging twice is noise
            if isinstance(target, ast.Name) and target.id in local_names:
                out.append(ctx.violation(
                    node, "ML302",
                    f"jax.jit of a callable created inside `{fn.name}` -- "
                    f"a fresh wrapper (and recompile) every call; hoist to "
                    f"module scope or wrap the factory in a bounded "
                    f"lru_cache"))
    return out


_LRU_BOUND_MAX = 64


def _contains_jit(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        d = dotted_name(node)
        if d and last_segment(d) in ("jit", "pjit"):
            return True
    return False


def _module_int_constants(tree: ast.Module) -> dict:
    consts = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            consts[node.targets[0].id] = node.value.value
    return consts


@rule("ML303", "recompile",
      "unbounded / oversized cache over compiled programs")
def check_cache_bounds(ctx):
    out: List = []
    consts = _module_int_constants(ctx.tree)
    for fn in astutil.function_defs(ctx.tree):
        for dec in fn.decorator_list:
            name = dotted_name(dec if not isinstance(dec, ast.Call)
                               else dec.func)
            seg = last_segment(name)
            if seg == "cache":
                out.append(ctx.violation(
                    dec, "ML303",
                    f"functools.cache on `{fn.name}` is unbounded; use "
                    f"lru_cache with maxsize <= {_LRU_BOUND_MAX}"))
                continue
            if seg != "lru_cache":
                continue
            def _resolve(v):
                if isinstance(v, ast.Constant):
                    return v.value
                if isinstance(v, ast.Name):
                    return consts.get(v.id)
                return None

            maxsize = None
            has_bound = False
            if isinstance(dec, ast.Call):
                if dec.args:
                    maxsize = _resolve(dec.args[0])
                    has_bound = maxsize is not None
                for kw in dec.keywords:
                    if kw.arg == "maxsize":
                        maxsize = _resolve(kw.value)
                        has_bound = maxsize is not None
            if not has_bound or maxsize is None:
                out.append(ctx.violation(
                    dec, "ML303",
                    f"lru_cache on `{fn.name}` without a finite maxsize is "
                    f"unbounded -- a long-lived server pins every entry"))
            elif isinstance(maxsize, int) and maxsize > _LRU_BOUND_MAX \
                    and _contains_jit(fn):
                out.append(ctx.violation(
                    dec, "ML303",
                    f"lru_cache(maxsize={maxsize}) on `{fn.name}` caches "
                    f"COMPILED PROGRAMS -- each entry pins an executable; "
                    f"bound it <= {_LRU_BOUND_MAX} (shape buckets are "
                    f"O(log n), the bound should be too)"))
    return out
