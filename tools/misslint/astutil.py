"""Shared AST helpers for the misslint rules.

Everything here is deliberately syntactic: misslint never imports the code
it analyses (importing would execute jax, trigger compiles, and make the
linter's verdict depend on the machine it runs on).  The cost is that every
judgement is a heuristic over names -- the rules are tuned so that the
codebase's sanctioned idioms come out clean and the known bug classes are
caught, with the baseline file absorbing the deliberate exceptions.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)
ScopeNode = FuncNode + (ast.Lambda, ast.ClassDef)

# Roots that produce traced values when called under jit.  ``jax.random``
# is included: its samplers return device arrays (and branching on them
# inside a trace is exactly the bug ML101 exists for).
TRACED_CALL_ROOTS = (
    "jnp.", "jax.numpy.", "lax.", "jax.lax.", "jax.random.", "jax.nn.",
)

# lax/jax combinators whose callable arguments are traced bodies: a local
# function handed to any of these is jit-reachable even without a decorator.
TRACING_COMBINATORS = {
    "while_loop", "fori_loop", "cond", "switch", "scan", "map",
    "associative_scan", "vmap", "pmap", "shard_map", "checkpoint", "remat",
    "custom_jvp", "custom_vjp", "grad", "value_and_grad", "pallas_call",
}


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def last_segment(dotted: Optional[str]) -> Optional[str]:
    return dotted.rsplit(".", 1)[-1] if dotted else None


def decorator_calls(fn: ast.AST) -> Iterator[ast.AST]:
    """Every decorator node, with ``partial(...)`` unwrapped one level so
    ``@partial(jax.jit, ...)`` yields both the partial call and jax.jit."""
    for dec in getattr(fn, "decorator_list", []):
        yield dec
        if isinstance(dec, ast.Call):
            name = call_name(dec)
            if name and last_segment(name) == "partial":
                for arg in dec.args:
                    yield arg


def _names_of(node: ast.AST) -> Set[str]:
    out = set()
    d = dotted_name(node)
    if d:
        out.add(d)
        out.add(last_segment(d))
    if isinstance(node, ast.Call):
        out |= _names_of(node.func)
    return out


def is_jit_decorated(fn: ast.AST) -> bool:
    for dec in decorator_calls(fn):
        names = _names_of(dec)
        if names & {"jax.jit", "jit", "pjit", "jax.pjit"}:
            return True
    return False


def has_cache_decorator(fn: ast.AST) -> bool:
    """lru_cache / functools.cache on the def -- the sanctioned wrapper for
    jit-returning factories (ML302's escape hatch; ML303 checks bounds)."""
    for dec in decorator_calls(fn):
        seg = last_segment(dotted_name(dec if not isinstance(dec, ast.Call)
                                       else dec.func))
        if seg in {"lru_cache", "cache"}:
            return True
    return False


def build_parents(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def build_qualnames(tree: ast.AST) -> Dict[ast.AST, str]:
    """Map every def/class to its dotted qualname (module scope = '')."""
    out: Dict[ast.AST, str] = {}

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncNode + (ast.ClassDef,)):
                qn = f"{prefix}.{child.name}" if prefix else child.name
                out[child] = qn
                visit(child, qn)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def enclosing_qualname(node: ast.AST, parents: Dict[ast.AST, ast.AST],
                       qualnames: Dict[ast.AST, str]) -> str:
    cur = node
    while cur is not None:
        if cur in qualnames:
            return qualnames[cur]
        cur = parents.get(cur)
    return "<module>"


def own_scope_walk(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested defs/classes
    (nested lambdas ARE descended -- they share the trace context)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, FuncNode + (ast.ClassDef,)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def function_defs(tree: ast.AST) -> List[ast.AST]:
    return [n for n in ast.walk(tree) if isinstance(n, FuncNode)]


def jit_reachable_functions(tree: ast.AST) -> Set[ast.AST]:
    """Defs whose bodies execute under a jax trace.

    Seeds: jit-decorated defs and local defs/lambdas passed to tracing
    combinators (lax.while_loop bodies, shard_map, pallas_call kernels...).
    Closure: every def nested inside a reachable def is reachable (it runs
    while tracing), and a local name handed to a combinator resolves to the
    def of that name anywhere in the module (misslint has no scopes-perfect
    resolver; same-name collisions are acceptable for a lint).
    """
    by_name: Dict[str, List[ast.AST]] = {}
    for fn in function_defs(tree):
        by_name.setdefault(fn.name, []).append(fn)

    reachable: Set[ast.AST] = set()
    for fn in function_defs(tree):
        if is_jit_decorated(fn):
            reachable.add(fn)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        seg = last_segment(call_name(node))
        if seg not in TRACING_COMBINATORS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                reachable.add(arg)
            elif isinstance(arg, ast.Name):
                reachable.update(by_name.get(arg.id, ()))
            elif isinstance(arg, ast.Call):
                # functools.partial(body_fn, ...) / pl.when(...)(fn)
                for inner in list(arg.args):
                    if isinstance(inner, ast.Name):
                        reachable.update(by_name.get(inner.id, ()))

    # Nested defs of reachable functions trace too.
    frontier = list(reachable)
    while frontier:
        fn = frontier.pop()
        for node in ast.walk(fn):
            if node is not fn and isinstance(node, FuncNode) \
                    and node not in reachable:
                reachable.add(node)
                frontier.append(node)
    return reachable


def assign_targets(stmt: ast.AST) -> List[ast.AST]:
    if isinstance(stmt, ast.Assign):
        return list(stmt.targets)
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        return [stmt.target]
    if isinstance(stmt, (ast.withitem,)) and stmt.optional_vars is not None:
        return [stmt.optional_vars]
    return []


def flatten_target_names(target: ast.AST) -> List[str]:
    """Names (incl. dotted attr paths) bound by an assignment target."""
    out: List[str] = []
    stack = [target]
    while stack:
        t = stack.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        elif isinstance(t, ast.Starred):
            stack.append(t.value)
        else:
            d = dotted_name(t)
            if d:
                out.append(d)
    return out


def expr_mentions(node: ast.AST, names: Set[str]) -> bool:
    """True if any Name/dotted-attr inside ``node`` is in ``names``."""
    for sub in ast.walk(node):
        d = dotted_name(sub)
        if d and (d in names or d.split(".", 1)[0] in names):
            return True
    return False


def positional_params(fn: ast.AST) -> List[str]:
    args = fn.args
    return [a.arg for a in list(args.posonlyargs) + list(args.args)]


def keyword_only_params(fn: ast.AST) -> List[str]:
    return [a.arg for a in fn.args.kwonlyargs]
