"""misslint driver: file walking, rule registry, violations, baseline.

A rule is a function ``check(ctx: FileContext) -> Iterable[Violation]``
registered with :func:`rule`.  Cross-file rules (the Pallas signature-drift
check) register with ``scope="tree"`` and receive the full list of file
contexts once per run.

Baselines: every violation has a stable fingerprint derived from
(relpath, rule, enclosing qualname, normalized source line) -- NOT the line
number, so unrelated edits above a baselined site don't churn the file.
Baseline entries suppress exactly one violation each; entries that no
longer match anything are reported as stale (the accepted debt was paid --
delete the line).
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import os
from pathlib import Path, PurePosixPath
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from . import astutil


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str           # repo-relative posix path
    line: int
    col: int
    rule: str           # e.g. "ML303"
    message: str
    scope: str          # enclosing qualname ("<module>" at top level)
    snippet: str        # stripped source line (fingerprint component)

    @property
    def fingerprint(self) -> str:
        norm = " ".join(self.snippet.split())
        raw = f"{self.path}|{self.rule}|{self.scope}|{norm}"
        return hashlib.sha1(raw.encode()).hexdigest()[:12]

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.scope}] {self.message}\n"
                f"    {self.snippet.strip()}")


class FileContext:
    """One parsed source file plus the lazily-built shared analyses."""

    def __init__(self, path: Path, relpath: str, source: str):
        self.path = path
        self.relpath = relpath          # posix, stable across machines
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self._parents = None
        self._qualnames = None
        self._jit_reachable = None

    @property
    def parents(self):
        if self._parents is None:
            self._parents = astutil.build_parents(self.tree)
        return self._parents

    @property
    def qualnames(self):
        if self._qualnames is None:
            self._qualnames = astutil.build_qualnames(self.tree)
        return self._qualnames

    @property
    def jit_reachable(self):
        if self._jit_reachable is None:
            self._jit_reachable = astutil.jit_reachable_functions(self.tree)
        return self._jit_reachable

    def scope_of(self, node: ast.AST) -> str:
        return astutil.enclosing_qualname(node, self.parents, self.qualnames)

    def snippet_at(self, node: ast.AST) -> str:
        line = getattr(node, "lineno", 0)
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""

    def violation(self, node: ast.AST, rule: str, message: str) -> Violation:
        return Violation(
            path=self.relpath, line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0), rule=rule, message=message,
            scope=self.scope_of(node), snippet=self.snippet_at(node))


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    family: str
    summary: str
    check: Callable
    scope: str = "file"     # "file" -> check(ctx); "tree" -> check(ctxs)


RULES: Dict[str, Rule] = {}


def rule(id: str, family: str, summary: str, *, scope: str = "file"):
    def register(fn):
        if id in RULES:
            raise ValueError(f"duplicate rule id {id}")
        RULES[id] = Rule(id=id, family=family, summary=summary,
                         check=fn, scope=scope)
        return fn
    return register


def _load_rules() -> None:
    from . import rules  # noqa: F401  (importing registers every rule)


def iter_source_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    seen, uniq = set(), []
    for f in out:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            uniq.append(f)
    return uniq


def _relpath(path: Path, rel_to: Optional[Path]) -> str:
    base = rel_to if rel_to is not None else Path.cwd()
    try:
        rel = path.resolve().relative_to(base.resolve())
    except ValueError:
        rel = path
    return str(PurePosixPath(rel))


def lint_paths(paths: Sequence[str], *,
               select: Optional[Sequence[str]] = None,
               rel_to: Optional[str] = None) -> List[Violation]:
    """Run every (selected) rule over the .py files under ``paths``.

    ``select``: rule ids or family names to run (default: all).
    ``rel_to``: base for the reported/fingerprinted relative paths
    (default: the current working directory).
    """
    _load_rules()
    active = list(RULES.values())
    if select:
        sel = set(select)
        active = [r for r in active if r.id in sel or r.family in sel]
        unknown = sel - {r.id for r in active} - {r.family for r in active}
        if unknown:
            raise ValueError(f"unknown rule/family selector(s): "
                             f"{sorted(unknown)}")
    base = Path(rel_to) if rel_to is not None else None
    ctxs: List[FileContext] = []
    violations: List[Violation] = []
    for f in iter_source_files(paths):
        try:
            source = f.read_text()
            ctx = FileContext(f, _relpath(f, base), source)
        except (SyntaxError, UnicodeDecodeError) as e:
            violations.append(Violation(
                path=_relpath(f, base), line=getattr(e, "lineno", 0) or 0,
                col=0, rule="ML000", message=f"unparseable: {e}",
                scope="<module>", snippet=""))
            continue
        ctxs.append(ctx)
    for ctx in ctxs:
        for r in active:
            if r.scope == "file":
                violations.extend(r.check(ctx))
    for r in active:
        if r.scope == "tree":
            violations.extend(r.check(ctxs))
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return violations


# -- baseline ---------------------------------------------------------------

def load_baseline(path: str) -> Dict[str, str]:
    """fingerprint -> original line (for stale reporting)."""
    entries: Dict[str, str] = {}
    p = Path(path)
    if not p.exists():
        return entries
    for line in p.read_text().splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("#"):
            continue
        entries[stripped.split()[0]] = stripped
    return entries


def write_baseline(path: str, violations: Sequence[Violation]) -> None:
    lines = [
        "# misslint baseline -- accepted pre-existing violations.",
        "# One entry suppresses exactly one violation; the fingerprint",
        "# hashes (path, rule, scope, source line), so entries survive",
        "# line drift but die when the flagged code actually changes.",
        "# Regenerate:  python -m tools.misslint src/repro --write-baseline",
        "#              (review the diff -- a GROWING baseline is a lint",
        "#               failure someone decided to ship; say why here.)",
        "",
    ]
    for v in violations:
        snip = " ".join(v.snippet.split())[:72]
        lines.append(f"{v.fingerprint}  {v.path}:{v.rule} {v.scope}  # {snip}")
    Path(path).write_text("\n".join(lines) + "\n")


def apply_baseline(
    violations: Sequence[Violation], baseline: Dict[str, str]
) -> Tuple[List[Violation], List[str]]:
    """Returns (new violations, stale baseline lines).

    Duplicate fingerprints (the same normalized line flagged twice in one
    scope) are suppressed together -- one entry covers them all; that is
    the pragmatic reading of "explicitly accepted".
    """
    matched: set = set()
    fresh: List[Violation] = []
    for v in violations:
        if v.fingerprint in baseline:
            matched.add(v.fingerprint)
        else:
            fresh.append(v)
    stale = [line for fp, line in baseline.items() if fp not in matched]
    return fresh, stale
