"""Distributed AQP demo: exact GROUP BY + Poisson-bootstrap error estimation
over a row-sharded dataset with shard_map + psum (8 simulated devices).

    PYTHONPATH=src python examples/distributed_aqp.py

Only (groups x moments) partials cross the interconnect -- the TPU-native
replacement for the paper's inverted-index scan avoidance (DESIGN.md SS3).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.aqp import distributed as D  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    N, m = 2_000_000, 4
    gid = rng.integers(0, m, N)
    x = (rng.standard_normal(N) + gid).astype(np.float32)

    mesh = D.make_data_mesh()
    print(f"mesh: {mesh.devices.size} devices, {N:,} rows sharded over "
          f"'data'")
    gid_s, x_s = D.shard_dataset(mesh, gid, x)

    stats = D.sharded_group_stats(mesh, gid_s, x_s, m)
    print("\nexact GROUP BY (one pass, psum of (m x 5) partials):")
    for g in range(m):
        cnt = float(stats['count'][g])
        print(f"  group {g}: count={cnt:,.0f} mean="
              f"{float(stats['sum'][g]) / cnt:.4f} "
              f"min={float(stats['min'][g]):.3f} "
              f"max={float(stats['max'][g]):.3f}")

    rate = jnp.full((m,), 0.02, jnp.float32)
    e, theta = D.sharded_bootstrap_estimate(mesh, gid_s, x_s, m, rate, 42,
                                            B=300)
    truth = np.asarray([x[gid == g].mean() for g in range(m)])
    print(f"\ndistributed 2% sample + Poisson bootstrap (B=300):")
    print(f"  estimate {np.asarray(theta).round(4)}")
    print(f"  truth    {truth.round(4)}")
    print(f"  certified L2 error (95%): {float(e):.4f}; "
          f"actual {np.linalg.norm(np.asarray(theta) - truth):.4f}")
    print(f"  network traffic: {m} groups x 301 replicates x 3 moments "
          f"floats = {m * 301 * 3 * 4 / 1024:.1f} KiB (data size independent)")


if __name__ == "__main__":
    main()
