"""End-to-end LM training driver: a ~10M-param Qwen2-family model trained
for a few hundred steps on the synthetic pipeline, with checkpointing,
resume, and a MISS-certified eval at the end.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(Scaled-down variant of launch/train.py; the same code path drives the
production mesh on real hardware.)
"""
import argparse
import shutil
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # Phase 1: train to steps//2, checkpointing.
        train_main(["--arch", "qwen2-1.5b", "--smoke",
                    "--steps", str(args.steps // 2),
                    "--batch", str(args.batch), "--seq", str(args.seq),
                    "--ckpt", ckpt, "--ckpt-every", "20", "--lr", "3e-3"])
        print("\n--- simulated restart: resuming from checkpoint ---\n")
        # Phase 2: restart resumes from the latest checkpoint (elastic path)
        loss = train_main(["--arch", "qwen2-1.5b", "--smoke",
                           "--steps", str(args.steps),
                           "--batch", str(args.batch), "--seq", str(args.seq),
                           "--ckpt", ckpt, "--ckpt-every", "50",
                           "--lr", "3e-3", "--eval-every",
                           str(args.steps)])
        print(f"\nfinal loss {loss:.4f}")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
