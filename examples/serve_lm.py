"""Serve a small model with continuously batched requests.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen3-1.7b", "--smoke", "--requests", "6",
                "--slots", "3", "--max-new", "12"])
