"""Quickstart: find the optimal sample size for an approximate AVG query.

    PYTHONPATH=src python examples/quickstart.py

Builds a 2-group dataset (Normal + Exponential, 400k rows each), asks MISS
for the minimal stratified sample certifying ||avg_hat - avg||_2 <= 0.02
with 95% confidence, and compares against the exact answer and the CLT
closed form (BLK).
"""
import numpy as np

from repro.core import baselines, estimators
from repro.core.l2miss import MissConfig, exact_answer, run_l2miss
from repro.data import make_grouped


def main():
    data = make_grouped(["normal", "exp"], 400_000, seed=1, biases=[5.0, 3.0])
    eps, delta = 0.02, 0.05
    print(f"dataset: {data.num_groups} groups x {data.sizes[0]:,} rows; "
          f"target ||err||_2 <= {eps} @ {1-delta:.0%}")

    cfg = MissConfig(epsilon=eps, delta=delta, B=300, n_min=500, n_max=1000,
                     l=8, seed=0)
    tr = run_l2miss(data, "avg", cfg)
    truth = exact_answer(data, estimators.get("avg")).ravel()
    err = float(np.linalg.norm(tr.theta.ravel() - truth))
    print(f"\nL2Miss: {tr.status} in {tr.iterations} iterations")
    print(f"  sample sizes per group: {tr.n}  (total {tr.total_sample_size:,}"
          f" of {data.sizes.sum():,} rows = "
          f"{tr.total_sample_size / data.sizes.sum():.2%})")
    print(f"  estimate {tr.theta.ravel().round(4)} vs truth {truth.round(4)}"
          f"  actual error {err:.4f} (bound {eps})")
    print(f"  model fit r^2 = {tr.info['r2']:.3f}")

    blk = baselines.run_blk(data, "avg", eps, delta)
    print(f"\nBLK (CLT closed form) total size: {int(blk.n.sum()):,} — "
          f"{blk.n.sum() / tr.total_sample_size:.2f}x the MISS sample, and "
          f"MISS needed no normality assumption")


if __name__ == "__main__":
    main()
