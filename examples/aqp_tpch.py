"""End-to-end AQP driver: a TPC-H query suite under error guarantees.

    PYTHONPATH=src python examples/aqp_tpch.py [--rows 1000000]

Builds a synthetic lineitem table, then serves a suite of Listing-1 queries
through the AQP engine: AVG / SUM / COUNT-with-predicate under L2 and Linf
bounds, plus an ordering-guaranteed Top-k -- each answered from a
MISS-optimal sample, with the exact answer computed for verification.
"""
import argparse
import time

import numpy as np

from repro.aqp import AQPEngine, Query
from repro.core.extensions import metric_value
from repro.data.tpch import add_group_bias, make_lineitem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    args = ap.parse_args()

    data, _ = make_lineitem(rows=args.rows, group_by="returnflag", seed=2)
    data = add_group_bias(data, 0.05)
    eng = AQPEngine(data, B=300, n_min=1000, n_max=2000, seed=0)
    print(f"lineitem: {args.rows:,} rows, {data.num_groups} RETURNFLAG groups")

    suite = [
        ("AVG(extendedprice) +-1%", Query(func="avg", epsilon_rel=0.01)),
        ("SUM(extendedprice) +-1%", Query(func="sum", epsilon_rel=0.01)),
        ("COUNT(price>30k) +-2%",
         Query(func="count", epsilon_rel=0.02,
               predicate=lambda v: v[:, 0] > 30_000.0)),
        ("AVG Linf +-100", Query(func="avg", epsilon=100.0, metric="linf")),
        ("AVG ordered (Top-k)", Query(func="avg", metric="order")),
    ]
    for name, q in suite:
        t0 = time.perf_counter()
        tr = eng.execute(q)
        dt = time.perf_counter() - t0
        truth = eng.exact(q)
        d = metric_value("l2" if q.metric == "order" else q.metric,
                         tr.theta.ravel(), truth.ravel())
        frac = tr.total_sample_size / data.sizes.sum()
        print(f"\n[{name}] {tr.status} in {dt:.1f}s, {tr.iterations} iters")
        print(f"  sampled {tr.total_sample_size:,} rows ({frac:.2%} of data)")
        print(f"  answer   {np.round(tr.theta.ravel(), 2)}")
        print(f"  exact    {np.round(truth.ravel(), 2)}")
        if q.metric == "order":
            ok = metric_value("order", tr.theta.ravel(), truth.ravel()) == 0
            print(f"  ordering preserved: {ok}")
        else:
            print(f"  {q.metric} error {d:.4g}")


if __name__ == "__main__":
    main()
