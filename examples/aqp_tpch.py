"""End-to-end AQP serving: a TPC-H query suite through the async session.

    PYTHONPATH=src python examples/aqp_tpch.py [--rows 1000000]

Builds a synthetic lineitem table and serves Listing-1 queries through the
asynchronous :class:`AQPSession` (DESIGN.md SS7 phase F): each request
carries an ERROR clause (epsilon, delta) AND an SLO envelope (deadline,
priority), is submitted into the live arrival queue, and is collected with
a non-blocking submit/poll/pump loop -- answers stream back as lanes
retire, tight-epsilon stragglers keep ticking while loose queries overtake
them through freed lanes.  Host-only queries (predicates, Linf, ordering)
ride the same session and route to the host engine.  The final batch goes
through ``AQPService.answer`` -- the synchronous compatibility wrapper
over the same session machinery.
"""
import argparse
import time

import numpy as np

from repro.aqp import AQPEngine, Query, Request
from repro.core.extensions import metric_value
from repro.data.tpch import add_group_bias, make_lineitem
from repro.serve import AQPService, AQPSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    args = ap.parse_args()

    data, _ = make_lineitem(rows=args.rows, group_by="returnflag", seed=2)
    data = add_group_bias(data, 0.05)
    sess = AQPSession(data, B=300, n_min=1000, n_max=2000, seed=0)
    eng: AQPEngine = sess.engine
    print(f"lineitem: {args.rows:,} rows, {data.num_groups} RETURNFLAG groups")

    # Absolute L2 bounds sized off the exact answer's magnitude (an example
    # convenience; production would use epsilon_rel through the host path).
    avg_mag = float(np.linalg.norm(eng.exact(Query(func="avg", epsilon=1.0))))
    sum_mag = float(np.linalg.norm(eng.exact(Query(func="sum", epsilon=1.0))))

    suite = [
        ("AVG +-1% (tight straggler)",
         Request(query=Query(func="avg", epsilon=0.01 * avg_mag),
                 deadline_s=120.0, priority=1)),
        ("AVG +-2%",
         Request(query=Query(func="avg", epsilon=0.02 * avg_mag),
                 deadline_s=60.0)),
        ("VAR +-5% of AVG-scale",
         Request(query=Query(func="var", epsilon=0.05 * avg_mag**2),
                 deadline_s=60.0)),
        ("SUM +-2%",
         Request(query=Query(func="sum", epsilon=0.02 * sum_mag),
                 deadline_s=60.0)),
        ("COUNT(price>30k) +-2% (host)",
         Request(query=Query(func="count", epsilon_rel=0.02,
                             predicate=lambda v: v[:, 0] > 30_000.0))),
        ("AVG ordered Top-k (host)",
         Request(query=Query(func="avg", metric="order"))),
    ]

    # --- async submit / poll loop: answers stream back as lanes retire ---
    pending = {}
    for name, req in suite:
        ticket = sess.submit(req)
        pending[ticket.rid] = (name, ticket, req)
        print(f"submitted [{name}] rid={ticket.rid}")
    while pending:
        sess.pump()                      # one non-blocking scheduler round
        for rid in list(pending):
            name, ticket, req = pending[rid]
            r = sess.poll(ticket)        # None while still in flight
            if r is None:
                continue
            del pending[rid]
            q = req.query
            truth = eng.exact(q)
            d = metric_value("l2" if q.metric == "order" else q.metric,
                             r.theta.ravel(), truth.ravel())
            slo = ("no deadline" if r.slo_met is None
                   else f"SLO {'met' if r.slo_met else 'MISSED'}")
            print(f"\n[{name}] via {r.route.value}: "
                  f"{'ok' if r.success else 'failed'} "
                  f"in {r.latency_s:.2f}s ({slo}, "
                  f"queue {r.queue_wait_s * 1e3:.0f}ms)")
            print(f"  answer {np.round(r.theta.ravel(), 2)}")
            print(f"  exact  {np.round(truth.ravel(), 2)}")
            if q.metric == "order":
                ok = metric_value("order", r.theta.ravel(),
                                  truth.ravel()) == 0
                print(f"  ordering preserved: {ok}")
            else:
                print(f"  {q.metric} error {d:.4g}")
        time.sleep(0.001)                # a real client would do other work

    st = sess.stats()
    print(f"\nsession: {st['completed']} served, "
          f"{st['fused_dispatches']} fused dispatches, "
          f"{st['rows_touched']:,} rows touched")

    # --- overload-native serving (DESIGN.md SS7 phase J) ------------------
    # With degrade=True the deadline becomes load-bearing: admission
    # relaxes epsilon to the largest bucket rung whose predicted cost fits
    # the remaining budget (a DEGRADED answer, relaxed bound reported in
    # delivered_epsilon), and a deadline that cannot be met even degraded
    # is SHED -- an immediate partial answer from a small pilot sample,
    # its measured error bar reported instead of queueing into a miss.
    print("\n--- overload-native: degraded + shed answers (phase J) ---")
    sess2 = AQPSession(data, B=300, n_min=1000, n_max=2000, seed=3,
                       degrade=True)
    tight = Query(func="avg", epsilon=0.005 * avg_mag)
    # Prime the admission cost model: a few full-fidelity runs teach it
    # the per-rung tick cost and the epsilon-vs-n sqrt law (an unprimed
    # model admits everything untouched -- degradation is never blind).
    for _ in range(3):
        sess2.submit(Request(query=tight, deadline_s=300.0))
    t0 = time.perf_counter()
    sess2.drain()
    full_s = (time.perf_counter() - t0) / 3
    # One throwaway shed compiles the pilot program (one per estimator
    # func); the showcased shed below is then a single warm dispatch.
    sess2.submit(Request(query=tight, deadline_s=1e-6))
    sess2.drain()

    def show(label, r, eps_req):
        kind = "shed" if r.shed else ("degraded" if r.degraded else "full")
        print(f"[{label}] {kind}: requested eps {eps_req:.4g} -> "
              f"delivered eps {r.delivered_epsilon:.4g} "
              f"(B={r.delivered_B}), n={np.round(np.mean(r.n)):.0f} "
              f"rows/group, {r.latency_s * 1e3:.1f}ms, "
              f"SLO {'met' if r.slo_met else 'MISSED'}")

    # Budget ~40% of the measured full-fidelity latency: enough for a
    # coarser rung, not for the requested epsilon.
    t_deg = sess2.submit(Request(query=tight, deadline_s=0.4 * full_s))
    r_deg = next(o for o in sess2.drain() if o.rid == t_deg.rid)
    show("tight deadline", r_deg, tight.epsilon)
    # A ~10ms budget is hopeless at any rung: shed at submit, answered
    # from the pilot before this call returns.
    t_shed = sess2.submit(Request(query=tight, deadline_s=0.010))
    r_shed = next(o for o in sess2.drain() if o.rid == t_shed.rid)
    show("blown deadline", r_shed, tight.epsilon)
    pst = sess2.stats()["pool"]
    print(f"pool counters: shed={pst['shed']} degraded={pst['degraded']} "
          f"migrations={pst['migrations']}")

    # --- the synchronous compat wrapper over the same machinery ---
    svc = AQPService(data, B=300, n_min=1000, n_max=2000, seed=1)
    batch = [Query(func="avg", epsilon=0.02 * avg_mag),
             Query(func="var", epsilon=0.05 * avg_mag**2),
             Query(func="sum", epsilon=0.02 * sum_mag)]
    t0 = time.perf_counter()
    rs = svc.answer(batch)
    print(f"\nAQPService.answer (compat wrapper): {len(rs)} queries in "
          f"{time.perf_counter() - t0:.2f}s, all "
          f"{'ok' if all(r.success for r in rs) else 'FAILED'}; "
          f"pool={'yes' if svc._lane_pool is not None else 'no'}")


if __name__ == "__main__":
    main()
